"""MC sweep-server launcher.

`python -m repro.launch.serve_mc` runs a demo traffic mix through the
coalescing server (`repro.serving.mc_server`) and prints the router's
batching stats; `--selftest` additionally pins the two serving
invariants on a mixed compatible/incompatible request set and exits
nonzero on violation — the CI `serve-smoke` job runs this mode:

  * K signature-compatible concurrent requests execute as ONE `_mc_core`
    compile — `trace_count()` equals the number of distinct signatures;
  * every demuxed per-request result matches a dedicated solo `run_mc`
    call to <= 1e-6 relative.

`--selftest --chaos` additionally drives the fault-tolerance paths (the
CI `chaos-smoke` job runs this): one injected engine-layer chunk fault
retried bit-identically, one transient quantum failure recovered under
`McServeConfig.retry`, and one mid-run deadline expiry resolving with a
`PartialResult` that matches a dedicated run over the completed seeds —
all on a virtual clock, no wall-clock sleeps.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import sys
import time

import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.mc import (
    MCProblemBatch,
    clear_cache,
    quadratic_mc_problem,
    run_mc,
    trace_count,
)
from repro.serving.mc_server import McServeConfig, SweepRequest, serve_sync


def _problem(n: int, dim: int, seed: int):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    return quadratic_mc_problem(x, y, 0.1, np.zeros(dim, np.float32))


def _demo_requests(steps: int, seeds: int) -> list:
    """A mixed set: three coalescible quadratic/gbma sweeps differing
    only in row data (N, noise, stepsize), plus one momentum request and
    one longer-horizon request — three distinct signatures."""
    mk = lambda n, noise, beta, seed: SweepRequest(
        problem=_problem(n, 8, seed),
        channels=[ChannelConfig(fading="rayleigh", noise_std=noise)],
        algo="gbma", betas=[beta], steps=steps, seeds=seeds)
    reqs = [mk(12, 0.5, 0.08, 0), mk(20, 1.0, 0.05, 1), mk(16, 0.1, 0.1, 2)]
    reqs.append(SweepRequest(
        problem=_problem(16, 8, 3),
        channels=[ChannelConfig(fading="rayleigh")],
        algo="momentum", betas=[0.05], steps=steps, seeds=seeds))
    reqs.append(SweepRequest(
        problem=_problem(12, 8, 4),
        channels=[ChannelConfig(fading="rayleigh")],
        algo="gbma", betas=[0.08], steps=steps + 10, seeds=seeds))
    return reqs


def _solo(req: SweepRequest):
    """The dedicated-call reference: the same row-based engine path the
    server uses, one request per call."""
    return run_mc(MCProblemBatch.stack([req.problem]),
                  req.channels, req.algo, req.betas,
                  req.steps, req.seeds, seed0=req.seed0,
                  batch_frac=req.batch_frac, n_antennas=req.n_antennas,
                  power_budget=req.power_budget, momentum=req.momentum,
                  theta0=req.theta0, shard_seeds=False)


def _selftest(steps: int, seeds: int, quantum: int,
              bucket_base: float = 2.0) -> int:
    # The demo mix spans two N-buckets inside the gbma signature, but a
    # fresh server has seen neither shape class — first sight merges
    # (compiles dominate the cost model's prediction), so the bucketed
    # router keeps the one-compile-per-signature invariant this test pins.
    reqs = _demo_requests(steps, seeds)
    n_sigs = 3
    clear_cache()
    results = serve_sync(reqs, McServeConfig(quantum_seeds=quantum,
                                             bucket_base=bucket_base))
    compiles = trace_count()
    stats = serve_sync.last_stats
    ok = True
    if compiles != n_sigs:
        ok = False
        print(f"FAIL: {compiles} compiles for {n_sigs} distinct "
              f"signatures ({len(reqs)} requests)")
    for i, (req, res) in enumerate(zip(reqs, results)):
        solo = _solo(req)
        rel = np.max(np.abs(res.risks - solo.risks)
                     / np.maximum(np.abs(solo.risks), 1e-12))
        if not (rel <= 1e-6):
            ok = False
            print(f"FAIL: request {i} demux mismatch, rel={rel:.3e}")
    n_batches = len(stats.batches)
    if n_batches != n_sigs:
        ok = False
        print(f"FAIL: {n_batches} batches for {n_sigs} signatures")
    if any(b["pad_flops_ratio"] < 1.0 for b in stats.batches):
        ok = False
        print("FAIL: pad_flops_ratio < 1.0 (padded FLOPs below useful)")
    verdict = "PASS" if ok else "FAIL"
    print(f"selftest {verdict}: {len(reqs)} requests -> {n_batches} "
          f"batches, {compiles} compiles, batches="
          f"{[(b['requests'], b['rows'], b['quanta']) for b in stats.batches]}, "
          f"pad_ratios="
          f"{[b['pad_flops_ratio'] for b in stats.batches]}, "
          f"occupancy={stats.bucket_occupancy}")
    return 0 if ok else 1


class _VirtualClock:
    """Injected server clock: advanced only by scripted events."""

    def __init__(self):
        self.now = 0.0

    def time(self) -> float:
        return self.now

    async def sleep(self, dt: float) -> None:
        self.now += dt
        await asyncio.sleep(0)


def _chaos(steps: int, seeds: int, quantum: int) -> int:
    """Chaos scenarios for `--selftest --chaos`: scripted faults at the
    engine and serving layers, each checked against its fault-free
    reference. Returns 0/1 like `_selftest`."""
    from repro.core.mc import ExecPlan, RetryPolicy
    from repro.core.mc import exec as exec_mod
    from repro.serving.mc_server import (
        InlineExecutor,
        McSweepServer,
        PartialResult,
    )

    ok = True

    def rel(a, b):
        return np.max(np.abs(np.asarray(a) - np.asarray(b))
                      / np.maximum(np.abs(np.asarray(b)), 1e-12))

    # -- scenario 0: engine-layer chunk retry is bit-identical ----------
    args = (_problem(12, 8, 0),
            [ChannelConfig(fading="rayleigh", noise_std=0.5)],
            "gbma", [0.08], steps, seeds)
    plan = ExecPlan(seed_chunk=quantum, keep_seed_curves=False)
    clean = run_mc(*args, plan=plan)
    fired = []

    def fail_first_attempts(info):
        if info["attempt"] == 1:  # every chunk fails once
            fired.append(info["off"])
            raise RuntimeError("chaos: injected chunk fault")

    remove = exec_mod.install_chunk_fault_hook(fail_first_attempts)
    try:
        survived = run_mc(*args, plan=plan.replace(
            retry=RetryPolicy(max_attempts=2, sleep=lambda dt: None)))
    finally:
        remove()
    if not (fired and np.array_equal(survived.mean, clean.mean)
            and np.array_equal(survived.ci95, clean.ci95)):
        ok = False
        print(f"FAIL: chunk retry not bit-identical after {len(fired)} "
              f"injected faults")

    class _ChaosExecutor(InlineExecutor):
        """Fails the `fail_at`-th engine call once; jumps the virtual
        clock by `jump` after the `jump_after`-th call (a scripted slow
        quantum)."""

        def __init__(self, clock, fail_at=None, jump_after=None,
                     jump=0.0):
            self.clock = clock
            self.fail_at = fail_at
            self.jump_after = jump_after
            self.jump = jump
            self.n = 0

        async def run(self, fn, info=None):
            idx, self.n = self.n, self.n + 1
            if idx == self.fail_at:
                self.fail_at = None
                raise RuntimeError("chaos: transient quantum failure")
            out = await super().run(fn, info)
            if idx == self.jump_after:
                self.clock.now += self.jump
            return out

    async def drive(srv, reqs):
        tasks = [asyncio.ensure_future(srv.submit(r)) for r in reqs]
        await asyncio.sleep(0)
        await srv.drain()
        return await asyncio.gather(*tasks, return_exceptions=True)

    # -- scenario 1: transient quantum failure recovered by cfg.retry ---
    req = _demo_requests(steps, seeds)[0]
    clock = _VirtualClock()
    srv = McSweepServer(
        McServeConfig(quantum_seeds=quantum,
                      retry=RetryPolicy(max_attempts=3,
                                        base_delay_s=0.01)),
        executor=_ChaosExecutor(clock, fail_at=0), clock=clock)
    (res,) = asyncio.run(drive(srv, [req]))
    retries = srv.stats.retries
    if isinstance(res, Exception) or retries < 1 \
            or rel(res.risks, _solo(req).risks) > 1e-6:
        ok = False
        print(f"FAIL: retried quantum did not recover to the solo "
              f"result ({res!r}, retries={retries})")

    # -- scenario 2: mid-run deadline expiry -> PartialResult -----------
    reqs = _demo_requests(steps, seeds)[:2]
    hurried = dataclasses.replace(reqs[0], deadline_s=5.0)
    patient = reqs[1]
    clock = _VirtualClock()
    srv = McSweepServer(
        McServeConfig(quantum_seeds=quantum),
        executor=_ChaosExecutor(clock, jump_after=0, jump=10.0),
        clock=clock)
    part, full = asyncio.run(drive(srv, [hurried, patient]))
    part_ref = dataclasses.replace(hurried, seeds=quantum,
                                   deadline_s=None)
    if not (isinstance(part, PartialResult)
            and part.seeds_completed == quantum
            and part.result is not None
            and rel(part.result.risks, _solo(part_ref).risks) <= 1e-6):
        ok = False
        print(f"FAIL: deadline expiry did not degrade gracefully "
              f"({part!r})")
    if isinstance(full, Exception) \
            or rel(full.risks, _solo(patient).risks) > 1e-6:
        ok = False
        print("FAIL: the expired request disturbed its batchmate")
    if srv.stats.deadline_expired != 1:
        ok = False
        print(f"FAIL: deadline_expired={srv.stats.deadline_expired}")

    verdict = "PASS" if ok else "FAIL"
    print(f"chaos {verdict}: {len(fired)} chunk faults retried "
          f"bit-identically, 1 quantum failure recovered "
          f"(retries={retries}), 1 deadline expiry -> "
          f"PartialResult({quantum}/{seeds} seeds)")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--quantum", type=int, default=4,
                    help="seeds per scheduling quantum")
    ap.add_argument("--bucket-base", type=float, default=2.0,
                    help="geometric N-bucket base of the pad-waste-aware "
                         "coalescer; <= 1 disables bucketing")
    ap.add_argument("--selftest", action="store_true",
                    help="assert one compile per distinct signature and "
                         "demux == solo run_mc; exit nonzero on failure")
    ap.add_argument("--chaos", action="store_true",
                    help="with --selftest: also run the scripted fault "
                         "scenarios (chunk retry, quantum retry, "
                         "deadline expiry)")
    args = ap.parse_args()
    if args.selftest:
        rc = _selftest(args.steps, args.seeds, args.quantum,
                       args.bucket_base)
        if args.chaos:
            rc |= _chaos(args.steps, args.seeds, args.quantum)
        sys.exit(rc)
    reqs = _demo_requests(args.steps, args.seeds)
    clear_cache()
    t0 = time.time()
    results = serve_sync(reqs, McServeConfig(quantum_seeds=args.quantum,
                                             bucket_base=args.bucket_base))
    dt = time.time() - t0
    stats = serve_sync.last_stats
    print(f"{len(reqs)} requests -> {len(stats.batches)} coalesced "
          f"batches, {trace_count()} compiles, {dt:.1f}s, "
          f"bucket occupancy {stats.bucket_occupancy}")
    for b in stats.batches:
        print(f"  sig={b['signature']} requests={b['requests']} "
              f"rows={b['rows']} seeds={b['seeds']} quanta={b['quanta']} "
              f"n_max={b['n_max']} pad_flops_ratio={b['pad_flops_ratio']}")
    for i, res in enumerate(results):
        print(f"  request {i}: final mean risk {res.mean[:, -1]}")


if __name__ == "__main__":
    main()
