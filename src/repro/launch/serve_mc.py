"""MC sweep-server launcher.

`python -m repro.launch.serve_mc` runs a demo traffic mix through the
coalescing server (`repro.serving.mc_server`) and prints the router's
batching stats; `--selftest` additionally pins the two serving
invariants on a mixed compatible/incompatible request set and exits
nonzero on violation — the CI `serve-smoke` job runs this mode:

  * K signature-compatible concurrent requests execute as ONE `_mc_core`
    compile — `trace_count()` equals the number of distinct signatures;
  * every demuxed per-request result matches a dedicated solo `run_mc`
    call to <= 1e-6 relative.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.mc import (
    MCProblemBatch,
    clear_cache,
    quadratic_mc_problem,
    run_mc,
    trace_count,
)
from repro.serving.mc_server import McServeConfig, SweepRequest, serve_sync


def _problem(n: int, dim: int, seed: int):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    return quadratic_mc_problem(x, y, 0.1, np.zeros(dim, np.float32))


def _demo_requests(steps: int, seeds: int) -> list:
    """A mixed set: three coalescible quadratic/gbma sweeps differing
    only in row data (N, noise, stepsize), plus one momentum request and
    one longer-horizon request — three distinct signatures."""
    mk = lambda n, noise, beta, seed: SweepRequest(
        problem=_problem(n, 8, seed),
        channels=[ChannelConfig(fading="rayleigh", noise_std=noise)],
        algo="gbma", betas=[beta], steps=steps, seeds=seeds)
    reqs = [mk(12, 0.5, 0.08, 0), mk(20, 1.0, 0.05, 1), mk(16, 0.1, 0.1, 2)]
    reqs.append(SweepRequest(
        problem=_problem(16, 8, 3),
        channels=[ChannelConfig(fading="rayleigh")],
        algo="momentum", betas=[0.05], steps=steps, seeds=seeds))
    reqs.append(SweepRequest(
        problem=_problem(12, 8, 4),
        channels=[ChannelConfig(fading="rayleigh")],
        algo="gbma", betas=[0.08], steps=steps + 10, seeds=seeds))
    return reqs


def _solo(req: SweepRequest):
    """The dedicated-call reference: the same row-based engine path the
    server uses, one request per call."""
    return run_mc(MCProblemBatch.stack([req.problem]),
                  req.channels, req.algo, req.betas,
                  req.steps, req.seeds, seed0=req.seed0,
                  batch_frac=req.batch_frac, n_antennas=req.n_antennas,
                  power_budget=req.power_budget, momentum=req.momentum,
                  theta0=req.theta0, shard_seeds=False)


def _selftest(steps: int, seeds: int, quantum: int,
              bucket_base: float = 2.0) -> int:
    # The demo mix spans two N-buckets inside the gbma signature, but a
    # fresh server has seen neither shape class — first sight merges
    # (compiles dominate the cost model's prediction), so the bucketed
    # router keeps the one-compile-per-signature invariant this test pins.
    reqs = _demo_requests(steps, seeds)
    n_sigs = 3
    clear_cache()
    results = serve_sync(reqs, McServeConfig(quantum_seeds=quantum,
                                             bucket_base=bucket_base))
    compiles = trace_count()
    stats = serve_sync.last_stats
    ok = True
    if compiles != n_sigs:
        ok = False
        print(f"FAIL: {compiles} compiles for {n_sigs} distinct "
              f"signatures ({len(reqs)} requests)")
    for i, (req, res) in enumerate(zip(reqs, results)):
        solo = _solo(req)
        rel = np.max(np.abs(res.risks - solo.risks)
                     / np.maximum(np.abs(solo.risks), 1e-12))
        if not (rel <= 1e-6):
            ok = False
            print(f"FAIL: request {i} demux mismatch, rel={rel:.3e}")
    n_batches = len(stats.batches)
    if n_batches != n_sigs:
        ok = False
        print(f"FAIL: {n_batches} batches for {n_sigs} signatures")
    if any(b["pad_flops_ratio"] < 1.0 for b in stats.batches):
        ok = False
        print("FAIL: pad_flops_ratio < 1.0 (padded FLOPs below useful)")
    verdict = "PASS" if ok else "FAIL"
    print(f"selftest {verdict}: {len(reqs)} requests -> {n_batches} "
          f"batches, {compiles} compiles, batches="
          f"{[(b['requests'], b['rows'], b['quanta']) for b in stats.batches]}, "
          f"pad_ratios="
          f"{[b['pad_flops_ratio'] for b in stats.batches]}, "
          f"occupancy={stats.bucket_occupancy}")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--quantum", type=int, default=4,
                    help="seeds per scheduling quantum")
    ap.add_argument("--bucket-base", type=float, default=2.0,
                    help="geometric N-bucket base of the pad-waste-aware "
                         "coalescer; <= 1 disables bucketing")
    ap.add_argument("--selftest", action="store_true",
                    help="assert one compile per distinct signature and "
                         "demux == solo run_mc; exit nonzero on failure")
    args = ap.parse_args()
    if args.selftest:
        sys.exit(_selftest(args.steps, args.seeds, args.quantum,
                           args.bucket_base))
    reqs = _demo_requests(args.steps, args.seeds)
    clear_cache()
    t0 = time.time()
    results = serve_sync(reqs, McServeConfig(quantum_seeds=args.quantum,
                                             bucket_base=args.bucket_base))
    dt = time.time() - t0
    stats = serve_sync.last_stats
    print(f"{len(reqs)} requests -> {len(stats.batches)} coalesced "
          f"batches, {trace_count()} compiles, {dt:.1f}s, "
          f"bucket occupancy {stats.bucket_occupancy}")
    for b in stats.batches:
        print(f"  sig={b['signature']} requests={b['requests']} "
              f"rows={b['rows']} seeds={b['seeds']} quanta={b['quanta']} "
              f"n_max={b['n_max']} pad_flops_ratio={b['pad_flops_ratio']}")
    for i, res in enumerate(results):
        print(f"  request {i}: final mean risk {res.mean[:, -1]}")


if __name__ == "__main__":
    main()
