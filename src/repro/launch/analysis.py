"""Compiled-artifact analysis: memory stats, HLO FLOPs/bytes, and collective
traffic parsed from the optimized HLO — the inputs to the §Roofline terms.

cost_analysis() numbers are PER-DEVICE (the SPMD module is the per-device
program); collective bytes likewise. Known limitation (documented in
EXPERIMENTS.md): XLA's HloCostAnalysis does not multiply `while`-loop bodies
by their trip count, so scan-over-layers compute is under-counted — we
therefore report the *analytic* model FLOPs alongside and use HLO numbers for
structure (collectives, memory) rather than absolute compute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO,
    multiplied by while-loop trip counts where inferable (scan bodies are
    separate computations called from a while op; XLA names them ..body..).
    """
    # map computation name -> accumulated collective bytes
    per_comp: Dict[str, Dict[str, int]] = {}
    comp = "main"
    for line in hlo_text.splitlines():
        striped = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*\([^)]*\)\s*->", striped)
        if striped.startswith(("ENTRY", "%")) and "{" in striped and "->" in striped:
            mm = re.search(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", striped)
            if mm:
                comp = mm.group(1)
            continue
        for op in _COLLECTIVES:
            # match "= <shape> op-name(" or "= (<tuple>) op-name("
            if re.search(rf"=\s*[^=]*\s{op}(?:-start|-done)?\(", striped):
                lhs = striped.split("=", 1)[1]
                shape_part = lhs.split(op)[0]
                b = _shape_bytes(shape_part)
                d = per_comp.setdefault(comp, {})
                d[op] = d.get(op, 0) + b
                break
    # trip counts: find while ops and their body computation names
    trip_counts: Dict[str, int] = {}
    for m in re.finditer(r"while\(.*?\), condition=%?([\w\.\-]+), "
                         r"body=%?([\w\.\-]+)", hlo_text):
        body = m.group(2)
        trip_counts.setdefault(body, 0)
    # XLA often annotates known trip counts
    for m in re.finditer(r"body=%?([\w\.\-]+).*?trip_count=\"?(\d+)", hlo_text):
        trip_counts[m.group(1)] = int(m.group(2))

    out: Dict[str, int] = {}
    for comp_name, d in per_comp.items():
        mult = 1
        for body, tc in trip_counts.items():
            if comp_name.startswith(body) or body == comp_name:
                mult = max(tc, 1)
        for op, b in d.items():
            out[op] = out.get(op, 0) + b * mult
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class RooflineTerms:
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    coll_bytes: float  # per device
    model_flops: float  # analytic, per device
    chips: int

    @property
    def compute_s(self) -> float:
        return max(self.hlo_flops, self.model_flops) / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        h = max(self.hlo_flops, self.model_flops)
        return self.model_flops / h if h else 0.0

    def as_dict(self) -> dict:
        return {
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


def memory_stats(compiled) -> dict:
    try:
        ms = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ms, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ms, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ms, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ms, "generated_code_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ms, "peak_memory_in_bytes", 0) or 0),
            "alias_bytes": int(getattr(ms, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        return {"flops": 0.0, "bytes_accessed": 0.0, "error": str(e)}
