import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) combo
lowers and compiles on the production mesh, and extract memory/cost/collective
statistics for §Dry-run and §Roofline of EXPERIMENTS.md.

MUST be executed as its own process (`python -m repro.launch.dryrun ...`):
the XLA_FLAGS line above runs before any jax import so 512 placeholder host
devices exist. Never set that flag globally — tests/benches expect 1 device.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, pair_runnable
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMAConfig
from repro.launch import analysis
from repro.launch.analytic import model_flops, param_counts
from repro.launch.mesh import make_production_mesh
from repro.models.model import SHAPES, build_model
from repro.optim.gd import gd
from repro.sharding.specs import (batch_shardings, cache_shardings,
                                  params_shardings, use_dp_over_model,
                                  use_mesh)
from repro.training.train_step import TrainConfig, build_train_step


def step_and_specs(model, shape, mesh, aggregator="gbma",
                   noise_dtype="float32", rng_impl="threefry2x32",
                   microbatches=1):
    """Build the step fn + (arg ShapeDtypeStructs, in_shardings)."""
    cfg = model.cfg
    n_nodes = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_nodes *= mesh.shape[a]
    params_shape = model.params_shape()
    p_sh = params_shardings(params_shape, cfg.fsdp, mesh)

    if shape.kind == "train":
        tcfg = TrainConfig(aggregator=aggregator,
                           gbma=GBMAConfig(n_nodes=n_nodes,
                                           channel=ChannelConfig(),
                                           noise_dtype=noise_dtype),
                           rng_impl=rng_impl, microbatches=microbatches)
        opt = gd(stepsize=1e-3)
        opt_state = jax.eval_shape(opt.init, params_shape)
        o_sh = jax.tree_util.tree_map(lambda _: None, opt_state)
        step = build_train_step(model, tcfg, opt)
        batch = model.input_specs(shape)
        b_sh = batch_shardings(batch, mesh)
        args = (params_shape, opt_state, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_sh, o_sh, b_sh, None)
        out_sh = ((p_sh, o_sh, None))
        fn = step
        donate = (0, 1)
    elif shape.kind == "prefill":
        batch = model.input_specs(shape)
        b_sh = batch_shardings(batch, mesh)
        args = (params_shape, batch)
        in_sh = (p_sh, b_sh)
        out_sh = None
        fn = model.prefill
        donate = ()
    else:  # decode
        cache_len = model.cache_len_for(shape)
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_len))
        c_sh = cache_shardings(cache, mesh)
        batch = model.input_specs(shape)
        args = (params_shape, cache, batch["token"],
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_sh, c_sh, batch_shardings(batch, mesh)["token"], None)
        out_sh = (None, c_sh)
        fn = model.decode_step
        donate = (1,)
    return fn, args, in_sh, out_sh, donate


def run_pair(arch: str, shape_name: str, mesh_kind: str,
             aggregator: str = "gbma", verbose: bool = True,
             opts: tuple = ()) -> dict:
    shape = SHAPES[shape_name]
    ok, reason = pair_runnable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    cfg = get_config(arch)
    noise_dtype = "float32"
    rng_impl = "threefry2x32"
    dp_over_model = False
    microbatches = 1
    for o in opts:  # §Perf switches, e.g. opt_pad_heads / opt_bf16_dispatch
        if o == "bf16_noise":
            noise_dtype = "bfloat16"
        elif o == "rbg":
            rng_impl = "rbg"
        elif o == "dp_over_model":
            dp_over_model = True
        elif o.startswith("micro"):
            microbatches = int(o[5:])
        else:
            cfg = cfg.with_(**{f"opt_{o}" if not o.startswith("opt_") else o:
                               True})
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with use_mesh(mesh), use_dp_over_model(dp_over_model):
            fn, args, in_sh, out_sh, donate = step_and_specs(
                model, shape, mesh, aggregator, noise_dtype, rng_impl,
                microbatches)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = analysis.memory_stats(compiled)
        cost = analysis.cost_stats(compiled)
        coll = analysis.collective_bytes(compiled.as_text())
        total_p, active_p = param_counts(model)
        terms = analysis.RooflineTerms(
            hlo_flops=cost["flops"],
            hlo_bytes=cost["bytes_accessed"],
            coll_bytes=float(coll.get("total", 0)),
            model_flops=model_flops(model, shape, chips),
            chips=chips,
        )
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "ok", "chips": chips,
            "params_total": total_p, "params_active": active_p,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": mem, "cost": cost, "collectives": coll,
            "roofline": terms.as_dict(),
        }
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] OK "
                  f"compile={t_compile:.0f}s "
                  f"args={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
                  f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
                  f"dominant={terms.dominant}", flush=True)
            print(f"  memory_analysis: {mem}", flush=True)
            print(f"  cost_analysis: flops={cost['flops']:.3e} "
                  f"bytes={cost['bytes_accessed']:.3e}", flush=True)
            print(f"  collectives: { {k: f'{v/2**20:.1f}MiB' for k, v in coll.items()} }",
                  flush=True)
        return rec
    except Exception as e:  # noqa: BLE001
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "error", "error": f"{type(e).__name__}: {e}"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("repro-100m",))
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--aggregator", default="gbma",
                    choices=("gbma", "fdm", "centralized"))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) pair")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--opts", default="",
                    help="comma list of §Perf switches: pad_heads,"
                         "bf16_dispatch,bf16_noise")
    args = ap.parse_args()
    opts = tuple(o for o in args.opts.split(",") if o)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    records = []
    for a, s in pairs:
        for mk in meshes:
            records.append(run_pair(a, s, mk, args.aggregator, opts=opts))
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".",
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = len(records) - n_ok - n_skip
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
