"""Analytic MODEL_FLOPS (6·N·D train / 2·N·D inference, MoE-active-aware,
plus attention term) — the 'useful compute' reference for §Roofline."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models.model import InputShape, Model


def param_counts(model: Model) -> tuple[int, int]:
    """(total_params, active_params_per_token)."""
    cfg = model.cfg
    shapes = model.params_shape()
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "experts_" in name:
            expert += n
    if cfg.n_experts:
        active = total - expert + expert * cfg.top_k // cfg.n_experts
    else:
        active = total
    return total, active


def _attention_flops(cfg: ModelConfig, batch: int, sq: int, skv: int,
                     causal: bool) -> float:
    """qk^T + pv MACs across layers, windowed layers at their window."""
    from repro.models.transformer import build_segments

    if cfg.family == "ssm":
        # wkv recurrence: per token per layer ~ 3 * H * hd * hd MACs
        h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
        return 2.0 * 3 * cfg.n_layers * batch * sq * h * hd * hd
    total = 0.0
    segs = build_segments(cfg) if cfg.family != "hybrid" else None
    layers = []
    if segs is None:  # hymba: every layer attn + ssm
        for i in range(cfg.n_layers):
            w = None if i in cfg.global_layer_ids else cfg.sliding_window
            layers.append(w)
    else:
        for seg in segs:
            for _ in range(seg.n_steps):
                for sub in seg.subs:
                    layers.append(sub.window)
    hd = cfg.qk_nope_dim + cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim
    hv = cfg.v_head_dim if cfg.use_mla else cfg.head_dim
    for w in layers:
        eff = min(w, skv) if w else skv
        kv_per_q = eff * (0.5 if (causal and sq > 1) else 1.0)
        total += 2.0 * batch * sq * kv_per_q * cfg.n_heads * (hd + hv)
    if cfg.family == "hybrid":  # ssm branch
        total += 2.0 * 3 * cfg.n_layers * batch * sq * cfg.d_model * cfg.ssm_state
    return total


def model_flops(model: Model, shape: InputShape, chips: int) -> float:
    """Analytic FLOPs per device for one step of `shape`."""
    cfg = model.cfg
    total, active = param_counts(model)
    b = shape.global_batch
    if shape.kind == "train":
        tokens = b * shape.seq_len
        f = 6.0 * active * tokens
        f += 3.0 * _attention_flops(cfg, b, shape.seq_len, shape.seq_len, True)
    elif shape.kind == "prefill":
        tokens = b * shape.seq_len
        f = 2.0 * active * tokens
        f += _attention_flops(cfg, b, shape.seq_len, shape.seq_len, True)
    else:  # decode: one token against a seq_len cache
        f = 2.0 * active * b
        f += _attention_flops(cfg, b, 1, shape.seq_len, False)
    return f / chips
