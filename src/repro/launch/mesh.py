"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e-class); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices but only {len(devices)} present; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
